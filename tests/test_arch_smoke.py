"""Per-architecture smoke tests (deliverable f): for each of the 10 assigned
archs, instantiate the REDUCED variant (2 layers, d_model<=256, <=4 experts) and
run one forward + one train step on CPU, asserting shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core import loglinear_schedule, masked_process
from repro.models import (
    decode_step,
    denoise_logits,
    encode,
    init_decode_state,
    init_params,
    param_count,
)
from repro.models.frontends import sample_frontend
from repro.train import OptimizerConfig, init_opt_state, make_train_step

ASSIGNED = [a for a in ARCH_IDS if a not in ("radd_small", "maskgit_small")]


def _extras(cfg, key, batch):
    return sample_frontend(key, cfg, batch)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward_and_train_step(arch, rng_key):
    cfg = get_config(arch, reduced=True)
    cfg.validate()
    assert cfg.n_layers == 2 and cfg.d_model <= 256 and cfg.n_experts <= 4
    params, axes = init_params(rng_key, cfg)
    assert param_count(params) > 0
    # axes tree mirrors params tree
    assert (jax.tree_util.tree_structure(
        jax.tree.map(lambda _: 0, params)) ==
        jax.tree_util.tree_structure(jax.tree.map(
            lambda _: 0, axes,
            is_leaf=lambda a: isinstance(a, tuple) and all(
                isinstance(x, (str, type(None))) for x in a))))

    b, l = 2, 16
    tokens = jax.random.randint(rng_key, (b, l), 0, cfg.vocab_size)
    extras = _extras(cfg, rng_key, b)
    logits, aux = denoise_logits(params, cfg, tokens, **extras)
    assert logits.shape == (b, l, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    # one train step
    proc = masked_process(cfg.vocab_size, loglinear_schedule())
    step = make_train_step(cfg, proc, OptimizerConfig(lr=1e-3, total_steps=10),
                           extra_input_names=tuple(extras))
    opt = init_opt_state(params, OptimizerConfig())
    new_params, new_opt, metrics = jax.jit(step)(
        params, opt, tokens, rng_key, *extras.values())
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt.step) == 1
    # params actually changed
    changed = jax.tree.map(lambda a, b_: bool(jnp.any(a != b_)), params, new_params)
    assert any(jax.tree_util.tree_leaves(changed))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_decode_step(arch, rng_key):
    cfg = get_config(arch, reduced=True)
    params, _ = init_params(rng_key, cfg)
    b = 2
    state = init_decode_state(cfg, batch=b, cache_len=8)
    enc_out = None
    if cfg.is_encdec:
        enc = jax.random.normal(rng_key, (b, cfg.encoder_seq, cfg.d_model)) * 0.02
        enc_out = encode(params, cfg, enc)
    tok = jnp.zeros((b, 1), jnp.int32)
    for pos in range(3):
        logits, state = decode_step(params, cfg, state, tok, jnp.int32(pos),
                                    encoder_out=enc_out)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    expect = {
        "starcoder2_7b": dict(n_layers=32, d_model=4608, n_heads=36,
                              n_kv_heads=4, d_ff=18432, vocab_size=49152),
        "internvl2_2b": dict(n_layers=24, d_model=2048, n_heads=16,
                             n_kv_heads=8, d_ff=8192, vocab_size=92553),
        "deepseek_v3_671b": dict(n_layers=61, d_model=7168, n_heads=128,
                                 vocab_size=129280, n_experts=256,
                                 experts_per_tok=8, moe_d_ff=2048),
        "whisper_tiny": dict(n_layers=4, d_model=384, n_heads=6, d_ff=1536,
                             vocab_size=51865, encoder_layers=4),
        "yi_34b": dict(n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
                       d_ff=20480, vocab_size=64000),
        "hymba_1_5b": dict(n_layers=32, d_model=1600, n_heads=25,
                           n_kv_heads=5, d_ff=5504, vocab_size=32001,
                           ssm_state=16),
        "starcoder2_15b": dict(n_layers=40, d_model=6144, n_heads=48,
                               n_kv_heads=4, d_ff=24576, vocab_size=49152),
        "mamba2_780m": dict(n_layers=48, d_model=1536, vocab_size=50280,
                            ssm_state=128),
        "minitron_4b": dict(n_layers=32, d_model=3072, n_heads=24,
                            n_kv_heads=8, d_ff=9216, vocab_size=256000),
        "grok_1_314b": dict(n_layers=64, d_model=6144, n_heads=48,
                            n_kv_heads=8, d_ff=32768, vocab_size=131072,
                            n_experts=8, experts_per_tok=2),
    }
    for arch, fields in expect.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"
        assert cfg.source, f"{arch} must cite its source"


def test_param_scale_sanity():
    """Full-config parameter counts are in the right ballpark (abstractly)."""
    import jax

    from repro.launch.specs import abstract_params

    expect_b = {"starcoder2_7b": (6, 9), "starcoder2_15b": (13, 18),
                "yi_34b": (30, 40), "mamba2_780m": (0.55, 1.0),
                "hymba_1_5b": (1.1, 2.2), "minitron_4b": (3.4, 6),
                "grok_1_314b": (250, 340),
                # 704B: all 61 layers MoE (the source keeps 3 dense) — DESIGN §7.
                "deepseek_v3_671b": (580, 720),
                "internvl2_2b": (1.5, 2.6), "whisper_tiny": (0.03, 0.09)}
    for arch, (lo, hi) in expect_b.items():
        specs, _ = abstract_params(get_config(arch))
        n = sum(int(np.prod(s.shape)) for s in jax.tree_util.tree_leaves(specs))
        assert lo * 1e9 <= n <= hi * 1e9, f"{arch}: {n/1e9:.2f}B params"
