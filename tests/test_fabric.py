"""Multi-host serving fabric: chaos suite (worker kills mid-run with
bit-exact recovery), heartbeat liveness, elastic join, transports."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    MaskedEngine,
    SamplerConfig,
    UniformEngine,
    loglinear_schedule,
    masked_process,
    uniform_process,
)
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.serve import (
    FabricRouter,
    FailureEvent,
    LoopbackTransport,
    PoolWorker,
    Request,
    ServingEngine,
    ServingFabric,
    failure_schedule,
)

CFG = ModelConfig(name="fab", family="dense", n_layers=2, d_model=64,
                  n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128,
                  vocab_size=23, dtype="float32")

POLICIES = ["round_robin", "join_shortest_queue", "least_remaining_nfe"]


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)[0]


# Cheap injected solver engines (same idiom as test_cluster.py): i.i.d.
# categorical scores keep each solver step a broadcast, so chaos runs spend
# their time in the scheduler — the thing under test.
_PI = jnp.asarray(np.random.default_rng(3).dirichlet(
    np.ones(CFG.vocab_size) * 2.0), jnp.float32)


def _iid_masked_engine():
    proc = masked_process(CFG.vocab_size, loglinear_schedule())
    return MaskedEngine(
        process=proc,
        score_fn=lambda toks, t: jnp.broadcast_to(
            _PI, toks.shape + (CFG.vocab_size,)))


def _iid_uniform_engine():
    uproc = uniform_process(CFG.vocab_size, loglinear_schedule())

    def ratio_fn(tokens, t):
        a = jnp.asarray(uproc.schedule.alpha(t))
        a = a.reshape(a.shape + (1,) * (tokens.ndim + 1 - a.ndim))
        pt = jnp.broadcast_to(a * _PI + (1 - a) / CFG.vocab_size,
                              tokens.shape + (CFG.vocab_size,))
        own = jnp.take_along_axis(pt, tokens[..., None], axis=-1)
        return pt / own

    return UniformEngine(process=uproc, score_fn=ratio_fn)


def make_fabric(params, n_workers=3, n_steps=3, max_batch=2, seq_len=12,
                **kw):
    proc = masked_process(CFG.vocab_size, loglinear_schedule())
    return ServingFabric(params, CFG, proc,
                         SamplerConfig(method="theta_trapezoidal",
                                       n_steps=n_steps, theta=0.5),
                         n_workers=n_workers, max_batch=max_batch,
                         seq_len=seq_len, **kw)


# --------------------------------------------------------------------------- #
# Chaos suite: kill a worker mid-run, per policy x engine — zero lost
# requests, tokens bit-identical to a failure-free single-pool run
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("engine_kind", ["masked", "uniform"])
def test_fabric_kill_midrun_token_parity(engine_kind, params):
    """The acceptance bar: a loopback run with a worker killed mid-flight
    completes ALL requests, and every request's tokens/steps/nfe are
    bit-identical to the failure-free single-pool run — for every router
    policy.  Recovery replays the dead worker's ledger with original
    (seed, request_id) keys, and tokens are placement-invariant."""
    solver_eng = (_iid_masked_engine() if engine_kind == "masked"
                  else _iid_uniform_engine())
    sampler = SamplerConfig(method="theta_trapezoidal", n_steps=3, theta=0.4)
    proc = solver_eng.process

    def requests():
        return [Request(request_id=i, seq_len=10, seed=i,
                        n_steps=(2 if i % 2 else 5)) for i in range(8)]

    base_eng = ServingEngine(params, CFG, proc, sampler, max_batch=2,
                             seq_len=10, solver_engine=solver_eng)
    for req in requests():
        base_eng.submit(req)
    base = {r.request_id: r for r in base_eng.run_all()}

    for policy in POLICIES:
        fab = ServingFabric(params, CFG, proc, sampler, n_workers=3,
                            max_batch=2, seq_len=10, policy=policy,
                            rebalance=True, heartbeat_timeout=2,
                            solver_engine=solver_eng)
        for req in requests():
            fab.submit(req)
        fab.kill_worker(0, at_tick=2)   # mid-run: work already dispatched
        got = {r.request_id: r for r in fab.run_all()}
        st = fab.stats()
        assert st.deaths == 1, (engine_kind, policy)
        assert base.keys() == got.keys(), (engine_kind, policy)  # zero lost
        for rid in base:
            assert (base[rid].tokens == got[rid].tokens).all(), \
                (engine_kind, policy)
            assert base[rid].steps == got[rid].steps
            assert base[rid].nfe == got[rid].nfe


def test_fabric_seeded_failure_schedule_run(params):
    """A full chaos scenario from one seed: failure_schedule drives kills and
    rejoins, and the run still completes every request bit-identically."""
    solver_eng = _iid_masked_engine()
    sampler = SamplerConfig(method="theta_trapezoidal", n_steps=3, theta=0.4)
    reqs = [Request(request_id=i, seq_len=10, seed=i) for i in range(10)]

    base_eng = ServingEngine(params, CFG, solver_eng.process, sampler,
                             max_batch=2, seq_len=10, solver_engine=solver_eng)
    for r in reqs:
        base_eng.submit(r)
    base = {r.request_id: np.asarray(r.tokens) for r in base_eng.run_all()}

    events = failure_schedule(n_workers=3, n_failures=2, horizon=6,
                              p_rejoin=1.0, seed=11)
    fab = ServingFabric(params, CFG, solver_eng.process, sampler, n_workers=3,
                        max_batch=2, seq_len=10, heartbeat_timeout=1,
                        solver_engine=solver_eng)
    fab.apply_failure_schedule(events)
    for r in [Request(request_id=i, seq_len=10, seed=i) for i in range(10)]:
        fab.submit(r)
    got = {r.request_id: np.asarray(r.tokens) for r in fab.run_all()}
    st = fab.stats()
    assert st.deaths == 2 and st.joins == 2
    assert base.keys() == got.keys()
    for rid in base:
        assert (base[rid] == got[rid]).all()


# --------------------------------------------------------------------------- #
# Heartbeat liveness on LoopbackTransport
# --------------------------------------------------------------------------- #


def test_heartbeat_timeout_declares_dead(params):
    """A worker whose heartbeats stop (control-plane failure — it may even
    still be computing) is declared dead after `heartbeat_timeout` silent
    ticks, fenced, and its ledger replayed: no request is lost."""
    fab = make_fabric(params, n_workers=2, max_batch=1,
                      heartbeat_timeout=2)
    fab.transport.drop_heartbeats(0, range(1, 100))
    for i in range(6):
        fab.submit(Request(request_id=i, seq_len=12, seed=i))
    results = fab.run_all()
    st = fab.stats()
    assert st.deaths == 1
    assert not fab._handles[0].alive
    assert fab._handles[0].died_tick == 3  # silent ticks 1..3 > timeout 2
    assert fab.transport.worker(0) is None  # fenced
    assert sorted(r.request_id for r in results) == list(range(6))
    assert all(r.worker == 1 for r in results if r.request_id in
               {e for e in range(6)} and fab._handles[0].served == 0)


def test_heartbeat_delay_within_timeout_is_tolerated(params):
    """Heartbeats arriving late (but inside the liveness window) carry stale
    load figures yet never kill the worker."""
    fab = make_fabric(params, n_workers=2, heartbeat_timeout=3)
    fab.transport.delay_heartbeats(0, 2)
    for i in range(6):
        fab.submit(Request(request_id=i, seq_len=12, seed=i))
    results = fab.run_all()
    st = fab.stats()
    assert st.deaths == 0
    assert sorted(r.request_id for r in results) == list(range(6))
    # Both workers kept serving.
    assert all(h.alive for h in fab._handles.values())


def test_heartbeat_drop_burst_shorter_than_timeout(params):
    """A burst of dropped heartbeats shorter than the timeout is survivable:
    no death, no replay, no duplicate results."""
    fab = make_fabric(params, n_workers=2, heartbeat_timeout=3)
    fab.transport.drop_heartbeats(0, [1, 2])
    for i in range(4):
        fab.submit(Request(request_id=i, seq_len=12, seed=i))
    results = fab.run_all()
    st = fab.stats()
    assert st.deaths == 0 and st.recovered == 0 and st.stale_results == 0
    assert sorted(r.request_id for r in results) == list(range(4))


# --------------------------------------------------------------------------- #
# Elastic join / leave
# --------------------------------------------------------------------------- #


def test_elastic_join_receives_rebalanced_work(params):
    """A worker joining mid-run immediately receives rebalanced QUEUED work
    and serves a share of the backlog."""
    fab = make_fabric(params, n_workers=1, max_batch=1, n_steps=4)
    for i in range(8):
        fab.submit(Request(request_id=i, seq_len=12, seed=i))
    fab.schedule_join(at_tick=2)
    results = fab.run_all()
    st = fab.stats()
    assert st.joins == 1 and st.n_spawned == 2
    assert st.rebalanced > 0  # the newcomer was fed from worker 0's queue
    served = {w["worker_id"]: w["served"] for w in st.per_worker}
    assert served[1] > 0
    assert sorted(r.request_id for r in results) == list(range(8))


def test_kill_then_rejoin_keeps_capacity(params):
    """Kill one of two workers, join a replacement: the fleet ends at the
    same live size and drains everything."""
    fab = make_fabric(params, n_workers=2, max_batch=1, heartbeat_timeout=1)
    for i in range(8):
        fab.submit(Request(request_id=i, seq_len=12, seed=i))
    fab.kill_worker(1, at_tick=2)
    fab.schedule_join(at_tick=5)
    results = fab.run_all()
    st = fab.stats()
    assert st.deaths == 1 and st.joins == 1
    assert st.n_workers == 2 and st.n_spawned == 3
    assert sorted(r.request_id for r in results) == list(range(8))


def test_fleet_extinction_raises(params):
    """All workers dead with work outstanding and no scheduled join is a
    stall — run_all refuses to spin forever."""
    fab = make_fabric(params, n_workers=2, heartbeat_timeout=1)
    for i in range(4):
        fab.submit(Request(request_id=i, seq_len=12, seed=i))
    fab.kill_worker(0)
    fab.kill_worker(1)
    with pytest.raises(RuntimeError, match="stalled"):
        fab.run_all()


# --------------------------------------------------------------------------- #
# Recovery accounting: original submit stamps survive replay
# --------------------------------------------------------------------------- #


def test_recovered_requests_keep_original_submit_stamp(params):
    """A replayed request's queue delay spans its ORIGINAL submit, not the
    requeue — the ledger carries the stamp (and Router.submit now accepts a
    submit_t passthrough for exactly this)."""
    fab = make_fabric(params, n_workers=2, max_batch=1, heartbeat_timeout=1)
    t0 = time.monotonic() - 100.0
    for i in range(4):
        fab.submit(Request(request_id=i, seq_len=12, seed=i), submit_t=t0)
    fab.kill_worker(0, at_tick=2)
    results = fab.run_all()
    assert fab.stats().recovered > 0
    for r in results:
        assert r.queue_delay_s >= 100.0   # spans the pre-dated submit
        assert r.latency_s >= r.queue_delay_s


def test_cluster_router_submit_t_passthrough(params):
    """The plain cluster Router honors the same passthrough."""
    from repro.serve import ServingCluster

    proc = masked_process(CFG.vocab_size, loglinear_schedule())
    cl = ServingCluster(params, CFG, proc,
                        SamplerConfig(method="theta_trapezoidal", n_steps=2,
                                      theta=0.5),
                        n_workers=2, max_batch=2, seq_len=12)
    cl.submit(Request(request_id=0, seq_len=12, seed=0),
              submit_t=time.monotonic() - 50.0)
    (res,) = cl.run_all()
    assert res.queue_delay_s >= 50.0


# --------------------------------------------------------------------------- #
# failure_schedule generator
# --------------------------------------------------------------------------- #


def test_failure_schedule_deterministic_and_valid():
    a = failure_schedule(n_workers=4, n_failures=3, horizon=20, seed=9)
    b = failure_schedule(n_workers=4, n_failures=3, horizon=20, seed=9)
    assert a == b                                    # one seed, one run
    assert a != failure_schedule(4, 3, 20, seed=10)
    assert len(a) == 3
    assert len({ev.worker_id for ev in a}) == 3      # without replacement
    assert [ev.kill_tick for ev in a] == sorted(ev.kill_tick for ev in a)
    for ev in a:
        assert 0 <= ev.worker_id < 4
        assert 1 <= ev.kill_tick < 20
        if ev.rejoin_tick is not None:
            assert ev.kill_tick < ev.rejoin_tick <= 20
    assert all(ev.rejoin_tick is None
               for ev in failure_schedule(4, 4, 20, p_rejoin=0.0, seed=1))
    assert all(ev.rejoin_tick is not None
               for ev in failure_schedule(4, 4, 20, p_rejoin=1.0, seed=1))


def test_failure_schedule_validation():
    with pytest.raises(ValueError, match="n_failures"):
        failure_schedule(2, -1, 10)
    with pytest.raises(ValueError, match="without replacement"):
        failure_schedule(2, 3, 10)
    with pytest.raises(ValueError, match="horizon"):
        failure_schedule(2, 1, 1)
    assert failure_schedule(2, 0, 10) == []


# --------------------------------------------------------------------------- #
# Transport-level semantics
# --------------------------------------------------------------------------- #


def test_loopback_kill_loses_state_and_submits_drop(params):
    proc = masked_process(CFG.vocab_size, loglinear_schedule())
    sampler = SamplerConfig(method="theta_trapezoidal", n_steps=2, theta=0.5)
    workers = [PoolWorker(i, ServingEngine(params, CFG, proc, sampler,
                                           max_batch=1, seq_len=12))
               for i in range(2)]
    tp = LoopbackTransport(workers)
    tp.submit(0, Request(request_id=0, seq_len=12, seed=0), 0.0)
    tp.kill(0)
    assert tp.alive_ids == [1]
    assert tp.worker(0) is None
    tp.kill(0)  # idempotent
    tp.submit(0, Request(request_id=1, seq_len=12, seed=1), 0.0)  # dropped
    assert tp.steal_queued(0) == []
    reports = tp.tick()
    assert 0 not in reports and reports[1].heartbeat is not None
    with pytest.raises(RuntimeError, match="spawn_worker"):
        tp.spawn()


def test_fabric_rejects_bad_config(params):
    with pytest.raises(ValueError, match="n_workers"):
        make_fabric(params, n_workers=0)
    with pytest.raises(ValueError, match="heartbeat_timeout"):
        make_fabric(params, n_workers=1, heartbeat_timeout=0)
    with pytest.raises(ValueError, match="transport"):
        make_fabric(params, n_workers=1, transport="carrier_pigeon")
    fab = make_fabric(params, n_workers=1)
    with pytest.raises(ValueError, match="seq_len"):
        fab.submit(Request(request_id=0, seq_len=64))


def test_fabric_stats_shape(params):
    fab = make_fabric(params, n_workers=2, policy="round_robin")
    for i in range(4):
        fab.submit(Request(request_id=i, seq_len=12, seed=i))
    fab.run_all()
    st = fab.stats()
    assert st.n_workers == 2 and st.policy == "round_robin"
    assert st.requests_served == 4 and st.dispatched == 4
    assert st.global_queued == 0 and st.in_flight == 0
    assert st.heartbeats > 0 and st.tick > 0
    assert st.latency_p95_s >= st.latency_p50_s >= 0.0
    assert {w["worker_id"] for w in st.per_worker} == {0, 1}
    assert "paid_slot_steps" in st.per_worker[0]["engine"]
    assert st.as_dict()["deaths"] == 0


# --------------------------------------------------------------------------- #
# ProcessTransport (opt-in: REPRO_FORCE_HOST_DEVICES=8 — the fabric-smoke
# CI job's path; each OS process anchors its engine to its own fake device)
# --------------------------------------------------------------------------- #


def test_process_transport_smoke(params, multi_device):
    """Multiprocess workers serve the same requests bit-identically to a
    loopback fleet built from the same param seed."""
    proc = masked_process(CFG.vocab_size, loglinear_schedule())
    sampler = SamplerConfig(method="theta_trapezoidal", n_steps=2, theta=0.5)

    oracle = ServingFabric(params, CFG, proc, sampler, n_workers=1,
                           max_batch=4, seq_len=12)
    reqs = [Request(request_id=i, seq_len=12, seed=7 + i) for i in range(6)]
    for r in reqs:
        oracle.submit(r)
    base = {r.request_id: np.asarray(r.tokens) for r in oracle.run_all()}

    fab = ServingFabric(params, CFG, proc, sampler, n_workers=2,
                        transport="process", max_batch=4, seq_len=12,
                        param_seed=0, tick_timeout_s=180.0)
    try:
        for r in [Request(request_id=i, seq_len=12, seed=7 + i)
                  for i in range(6)]:
            fab.submit(r)
        got = {r.request_id: np.asarray(r.tokens) for r in fab.run_all()}
        assert base.keys() == got.keys()
        for rid in base:
            assert (base[rid] == got[rid]).all()
        st = fab.stats()
        assert st.heartbeats > 0 and st.deaths == 0
        with pytest.raises(ValueError, match="stream_cb"):
            fab.submit(Request(request_id=99, seq_len=12, seed=0,
                               stream_cb=lambda *a: None))
    finally:
        fab.close()


def test_process_transport_rejects_loopback_only_features(params):
    proc = masked_process(CFG.vocab_size, loglinear_schedule())
    sampler = SamplerConfig(method="theta_trapezoidal", n_steps=2, theta=0.5)
    with pytest.raises(ValueError, match="solver_engine"):
        ServingFabric(params, CFG, proc, sampler, n_workers=1,
                      transport="process", solver_engine=_iid_masked_engine())
    with pytest.raises(ValueError, match="extra_inputs"):
        ServingFabric(params, CFG, proc, sampler, n_workers=1,
                      transport="process", extra_inputs={"pos": 1})
